//! Integration tests: whole-flow behaviour across technologies, sizes
//! and algorithms — the paper-shape assertions of DESIGN.md §4 that do
//! not need PJRT artifacts (those live in `runtime_artifacts.rs`).

use vstpu::cadflow::{CadFlow, FlowConfig, VivadoFlow, VtrFlow};
use vstpu::cluster::{hierarchical, Algorithm};
use vstpu::netlist::SystolicNetlist;
use vstpu::power::PowerModel;
use vstpu::razor::DEFAULT_TOGGLE;
use vstpu::tech::Technology;
use vstpu::timing;
use vstpu::{fpga, metrics, report};

fn slacks_16() -> Vec<f64> {
    let tech = Technology::artix7_28nm();
    let nl = SystolicNetlist::generate(16, &tech, 100.0, 2021);
    timing::synthesize(&nl)
        .min_slack_per_mac(16)
        .iter()
        .map(|s| s.min_slack_ns)
        .collect()
}

// ---------------------------------------------------------------- E7: Table II

#[test]
fn table2_every_tech_and_size_shapes() {
    // Paper reductions (static rails): Vivado ~6.37-6.76%, VTR 22nm
    // ~1.86-1.95%, 45nm ~1.77-1.87%, 130nm ~0.7-0.77%.
    let expect: &[(&str, f64, f64)] = &[
        ("artix7-28nm", 4.5, 8.0),
        ("academic-22nm", 1.2, 2.6),
        ("academic-45nm", 1.2, 2.6),
        ("academic-130nm", 0.3, 1.2),
    ];
    for tech in Technology::paper_suite() {
        let (_, lo, hi) = expect.iter().find(|(n, ..)| *n == tech.name).unwrap();
        for size in [16u32, 32, 64] {
            let mut cfg = FlowConfig::paper_default(size, tech.clone());
            cfg.calibrate = false;
            let rep = CadFlow::new(cfg).run().unwrap();
            assert!(
                rep.power.reduction_pct >= *lo && rep.power.reduction_pct <= *hi,
                "{} {}x{}: reduction {:.2}% outside [{lo}, {hi}]",
                tech.name,
                size,
                size,
                rep.power.reduction_pct
            );
            // Rails are the paper's rounded 0.96..0.99 ladder.
            let want = [0.99375, 0.98125, 0.96875, 0.95625];
            for (got, want) in rep.static_rails.iter().zip(want) {
                assert!((got - want).abs() < 1e-9);
            }
        }
    }
}

#[test]
fn table2_absolute_power_matches_paper_within_5pct() {
    let paper: &[(&str, u32, f64)] = &[
        ("artix7-28nm", 16, 408.0),
        ("artix7-28nm", 32, 1538.0),
        ("artix7-28nm", 64, 5920.0),
        ("academic-22nm", 16, 269.0),
        ("academic-22nm", 32, 1072.0),
        ("academic-22nm", 64, 4284.0),
        ("academic-45nm", 16, 387.0),
        ("academic-45nm", 32, 1549.0),
        ("academic-45nm", 64, 6200.0),
        ("academic-130nm", 16, 1543.0),
        ("academic-130nm", 32, 6172.0),
        ("academic-130nm", 64, 24693.0),
    ];
    for (name, size, mw) in paper {
        let tech = Technology::by_name(name).unwrap();
        let model = PowerModel::new(tech, 100.0);
        let ours = model.baseline_mw((size * size) as usize, 1.0);
        let err = (ours - mw).abs() / mw;
        assert!(err < 0.05, "{name} {size}: {ours:.0} vs paper {mw} ({err:.3})");
    }
}

#[test]
fn table2_fourth_instance_vivado_unsupported_vtr_supported() {
    // Vivado: "not supported" for critical-region rails.
    let mut cfg = FlowConfig::paper_default(64, Technology::artix7_28nm());
    cfg.v_lo = 0.65;
    cfg.v_hi = 1.05;
    assert!(VivadoFlow::new(cfg).run().is_err());

    // VTR: supported; reductions ordered 22nm > 45nm > 130nm as in the
    // paper (3.7% / 2.4% / 1.37%).
    let mut reductions = Vec::new();
    for tech in [
        Technology::academic_22nm(),
        Technology::academic_45nm(),
        Technology::academic_130nm(),
    ] {
        let mut cfg = FlowConfig::paper_default(64, tech.clone());
        // Paper rails {0.7, 0.8, 0.9, 1.0}; 0.7 V sits *at* the 130nm
        // threshold, so the flow clamps the range bottom above V_th.
        cfg.v_lo = (tech.v_th + 0.05).max(0.65);
        cfg.v_hi = cfg.v_lo + 0.40;
        cfg.calibrate = false;
        let rep = VtrFlow::new(cfg).run().unwrap();
        reductions.push(rep.power.reduction_pct);
    }
    assert!(
        reductions[0] > reductions[1] && reductions[1] > reductions[2],
        "expected 22nm > 45nm > 130nm, got {reductions:?}"
    );
}

// --------------------------------------------------------- E2: Figs 4 & 5

#[test]
fn fig4_5_partitioning_barely_moves_worst_paths() {
    let cfg = FlowConfig::paper_default(16, Technology::artix7_28nm());
    let rep = CadFlow::new(cfg).run().unwrap();
    for (deltas, what, tol) in [
        (&rep.fig4_setup_deltas, "setup", 0.15),
        (&rep.fig5_hold_deltas, "hold", 0.15),
    ] {
        assert_eq!(deltas.len(), 100);
        for (to, before, after) in deltas {
            assert!(after.is_finite(), "{what}: unmatched {to}");
            let rel = (after - before).abs() / before;
            assert!(rel < tol, "{what} path {to} moved {rel:.3}");
        }
    }
    // And criticality ordering survives (no re-clustering needed).
    assert!(rep.stage_slack_correlation > 0.95);
}

// ------------------------------------------------- E3-E6: Figs 10-14

#[test]
fn fig10_dendrogram_top_branch_is_tallest() {
    let slacks = slacks_16();
    let d = hierarchical::dendrogram(&slacks);
    let h = d.top_merge_heights(3);
    // "The length of the branch joining the last two clusters is the
    // highest, followed by the third and fourth clusters."
    assert!(h[0] > h[1] && h[1] >= h[2]);
    // The largest-gap criterion lands on a real band boundary (the four
    // row bands are equally spaced, so the binary split is the tallest
    // branch — k=2 or k=4 are both faithful cuts).
    let k = d.suggest_k(8);
    assert!(k == 2 || k == 4, "suggested k = {k}");
    // Cutting at 4 recovers the row bands exactly.
    assert_eq!(d.cut(4).unwrap().sizes().iter().sum::<usize>(), 256);
}

#[test]
fn fig11_hierarchical_k2_k3_k4() {
    let slacks = slacks_16();
    for k in [2usize, 3, 4] {
        let c = Algorithm::Hierarchical { k }.run(&slacks).unwrap();
        assert_eq!(c.k, k);
        let sizes = c.sizes();
        assert!(sizes.iter().all(|&s| s > 0), "k={k}: {sizes:?}");
        // Band structure: cutting at k=4 recovers the 64-MAC row bands.
        if k == 4 {
            assert_eq!(sizes, vec![64, 64, 64, 64]);
        }
    }
}

#[test]
fn fig12_kmeans_k3_k4_k5() {
    let slacks = slacks_16();
    for k in [3usize, 4, 5] {
        let c = Algorithm::KMeans { k, seed: 2021 }.run(&slacks).unwrap();
        assert_eq!(c.k, k);
        assert!(c.sizes().iter().all(|&s| s > 0));
    }
    let c4 = Algorithm::KMeans { k: 4, seed: 2021 }.run(&slacks).unwrap();
    assert_eq!(c4.sizes(), vec![64, 64, 64, 64]);
}

#[test]
fn fig13_meanshift_r04_yields_4_clusters() {
    // "Setting the radius as 0.4 for the slack values of a 16x16
    // systolic array yields 4 clusters."
    let slacks = slacks_16();
    let c = Algorithm::MeanShift { bandwidth: 0.4 }.run(&slacks).unwrap();
    assert_eq!(c.k, 4, "sizes {:?}", c.sizes());
}

#[test]
fn fig14_dbscan_recovers_bands_and_flags_outliers() {
    let mut slacks = slacks_16();
    let c = Algorithm::paper_default().run(&slacks).unwrap();
    assert_eq!(c.k, 4);
    assert_eq!(c.sizes(), vec![64, 64, 64, 64]);
    // Inject an outlier MAC (e.g. a pathological placement) — DBSCAN
    // must mark it as noise, "unlike other algorithms which throw all
    // points into a cluster".
    slacks[100] = 9.5;
    let c = Algorithm::paper_default().run(&slacks).unwrap();
    assert!(c.noise_points().contains(&100));
}

#[test]
fn clustering_algorithms_agree_on_band_structure() {
    let slacks = slacks_16();
    let reference = Algorithm::Hierarchical { k: 4 }.run(&slacks).unwrap();
    for algo in [
        Algorithm::KMeans { k: 4, seed: 1 },
        Algorithm::paper_default(),
    ] {
        let c = algo.run(&slacks).unwrap();
        let agree = reference
            .labels
            .iter()
            .zip(&c.labels)
            .filter(|(a, b)| a == b)
            .count();
        assert!(
            agree >= 250,
            "{} agrees on only {agree}/256 labels",
            algo.name()
        );
    }
}

// ---------------------------------------------------- E8/E9: Figs 15-16

/// Mirror of the CLI's variant table (kept in sync by the bench).
fn variant_power(tech: &Technology, shapes: &[(usize, (u32, u32), Vec<f64>)]) -> Vec<f64> {
    let model = PowerModel::new(tech.clone(), 100.0).with_kappa(0.85);
    shapes
        .iter()
        .map(|(_, (n, m), volts)| {
            volts
                .iter()
                .map(|&v| model.macs_power_mw((n * m) as usize, v, DEFAULT_TOGGLE))
                .sum::<f64>()
                + model.tech.p_overhead_mw
        })
        .collect()
}

#[test]
fn fig15_16_min_power_variant_is_most_macs_at_lowest_v() {
    // Paper: "the 2x(32x64){0.5,0.6} variant ... consumes minimum
    // dynamic power" on 22/45nm; "{0.7,0.8} ... in 130nm".
    for tech in [
        Technology::academic_22nm(),
        Technology::academic_45nm(),
        Technology::academic_130nm(),
    ] {
        let lo = if tech.node_nm == 130 { 0.7 } else { 0.5 };
        let shapes: Vec<(usize, (u32, u32), Vec<f64>)> = vec![
            (1, (64, 64), vec![1.0]),
            (2, (32, 64), vec![lo, lo + 0.1]),
            (4, (32, 32), vec![lo, lo + 0.1, lo + 0.2, lo + 0.3]),
            (4, (32, 32), vec![0.8, 1.0, 1.2, 1.3]),
        ];
        let power = variant_power(&tech, &shapes);
        let min_idx = power
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .unwrap()
            .0;
        assert_eq!(min_idx, 1, "{}: power {power:?}", tech.name);
        // Spread between best and worst variant is tens of percent.
        let max = power.iter().cloned().fold(0.0, f64::max);
        let spread = 100.0 * (max - power[min_idx]) / max;
        assert!(
            spread > 15.0 && spread < 75.0,
            "{}: spread {spread:.1}%",
            tech.name
        );
    }
}

#[test]
fn fig15_16_power_monotone_in_sum_v_squared() {
    // Power must track sum(n_macs * V^2) across variants: same MACs at
    // higher rails always cost more.
    let tech = Technology::academic_22nm();
    let shapes: Vec<(usize, (u32, u32), Vec<f64>)> = vec![
        (2, (32, 64), vec![0.5, 0.6]),
        (2, (32, 64), vec![0.7, 0.8]),
        (2, (32, 64), vec![0.9, 1.0]),
        (4, (32, 32), vec![0.9, 1.0, 1.1, 1.2]),
    ];
    let power = variant_power(&tech, &shapes);
    assert!(power[0] < power[1] && power[1] < power[2] && power[2] < power[3]);
}

// ------------------------------------------------ flow-level invariants

#[test]
fn all_four_algorithms_drive_the_full_flow() {
    for algo in [
        Algorithm::Hierarchical { k: 4 },
        Algorithm::KMeans { k: 4, seed: 2021 },
        Algorithm::MeanShift { bandwidth: 0.4 },
        Algorithm::paper_default(),
    ] {
        let cfg = FlowConfig::clustered(16, Technology::artix7_28nm(), algo.clone());
        let rep = CadFlow::new(cfg).run().unwrap();
        assert!(rep.n_partitions >= 2, "{}", algo.name());
        assert!(rep.power.reduction_pct > 0.0, "{}", algo.name());
        assert!(rep.calibration_converged, "{}", algo.name());
    }
}

#[test]
fn bigger_arrays_yield_similar_relative_savings() {
    // The paper's % reduction is roughly size-independent (6.37 / 6.76 /
    // 6.52 for 16/32/64 on Vivado).
    let mut r = Vec::new();
    for size in [16u32, 32, 64] {
        let mut cfg = FlowConfig::paper_default(size, Technology::artix7_28nm());
        cfg.calibrate = false;
        r.push(CadFlow::new(cfg).run().unwrap().power.reduction_pct);
    }
    let spread =
        r.iter().cloned().fold(0.0, f64::max) - r.iter().cloned().fold(f64::MAX, f64::min);
    assert!(spread < 1.5, "reductions {r:?}");
}

#[test]
fn constraint_files_cover_every_mac() {
    let cfg = FlowConfig::paper_default(16, Technology::artix7_28nm());
    let rep = CadFlow::new(cfg).run().unwrap();
    assert_eq!(rep.constraint_file.matches("add_cells_to_pblock").count(), 256);
    assert_eq!(rep.constraint_file.matches("create_pblock").count(), 4);
    // VTR flavour.
    let cfg = FlowConfig::paper_default(16, Technology::academic_22nm());
    let rep = VtrFlow::new(cfg).run().unwrap();
    assert_eq!(rep.constraint_file.matches("set_property REGION").count(), 256);
}

#[test]
fn calibrated_rails_never_exceed_static_on_vivado() {
    let cfg = FlowConfig::paper_default(16, Technology::artix7_28nm());
    let rep = CadFlow::new(cfg).run().unwrap();
    for (s, c) in rep.static_rails.iter().zip(&rep.calibrated_rails) {
        assert!(c <= s, "calibration raised a rail: {s} -> {c}");
        assert!(*c >= 0.95 - 1e-12, "left the guard band on Vivado: {c}");
    }
    let pc = rep.power_calibrated.unwrap();
    assert!(pc.scaled_total_mw <= rep.power.scaled_total_mw + 1e-9);
}

#[test]
fn vtr_calibration_descends_into_critical_region() {
    let cfg = FlowConfig::paper_default(16, Technology::academic_22nm());
    let rep = CadFlow::new(cfg).run().unwrap();
    // The academic flow may leave the guard band; at 100 MHz there is
    // real slack so at least one rail must end below 0.95 V.
    assert!(
        rep.calibrated_rails.iter().any(|&v| v < 0.95),
        "rails {:?}",
        rep.calibrated_rails
    );
    let pc = rep.power_calibrated.unwrap();
    assert!(pc.reduction_pct > rep.power.reduction_pct);
}

#[test]
fn seed_changes_jitter_but_not_the_shape() {
    for seed in [1u64, 7, 99] {
        let mut cfg = FlowConfig::paper_default(16, Technology::artix7_28nm());
        cfg.seed = seed;
        cfg.calibrate = false;
        let rep = CadFlow::new(cfg).run().unwrap();
        assert!(
            rep.power.reduction_pct > 4.5 && rep.power.reduction_pct < 8.0,
            "seed {seed}: {:.2}%",
            rep.power.reduction_pct
        );
        assert!(rep.stage_slack_correlation > 0.95, "seed {seed}");
    }
}

#[test]
fn report_renderers_produce_complete_artifacts() {
    let cfg = FlowConfig::paper_default(16, Technology::artix7_28nm());
    let rep = CadFlow::new(cfg).run().unwrap();
    let t2 = report::text_table(&report::TABLE2_HEADERS, &report::table2_block(&rep));
    assert!(t2.contains("% of Reduction"));
    let f4 = report::fig4_5_csv(&rep.fig4_setup_deltas);
    assert_eq!(f4.lines().count(), 101);
    let slacks = slacks_16();
    let c = Algorithm::paper_default().run(&slacks).unwrap();
    let csv = report::clustering_csv(&slacks, &c);
    assert_eq!(csv.lines().count(), 257);
}

// ------------------------------------------------ device/floorplan edge

#[test]
fn flow_runs_on_all_even_sizes() {
    for size in [4u32, 8, 24, 48] {
        let mut cfg = FlowConfig::paper_default(size, Technology::artix7_28nm());
        cfg.calibrate = false;
        let rep = CadFlow::new(cfg).run().unwrap();
        assert_eq!(
            rep.partition_sizes.iter().sum::<usize>(),
            (size * size) as usize
        );
    }
}

#[test]
fn quadrant_floorplan_matches_fig8_geometry() {
    let device = fpga::Device::for_array(16);
    let slacks = slacks_16();
    let clustering = vstpu::cadflow::equal_quartile_clustering(&slacks);
    let parts = vstpu::floorplan::quadrants(&device, &clustering, 16).unwrap();
    // Four islands, pairwise disjoint, each 64 MACs, arranged 2x2.
    assert_eq!(parts.len(), 4);
    let xs: std::collections::HashSet<u32> = parts.iter().map(|p| p.rect.x0).collect();
    let ys: std::collections::HashSet<u32> = parts.iter().map(|p| p.rect.y0).collect();
    assert_eq!(xs.len(), 2);
    assert_eq!(ys.len(), 2);
}

#[test]
fn min_slack_correlates_with_row_band() {
    // The physical story: row band index predicts min slack.
    let slacks = slacks_16();
    let bands: Vec<f64> = (0..256).map(|i| (i / 64) as f64).collect();
    let corr = metrics::pearson(&bands, &slacks);
    assert!(corr < -0.9, "band/slack correlation {corr}");
}
