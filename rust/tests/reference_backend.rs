//! Artifact-free runtime correctness: manifest.tsv error handling and
//! the pure-Rust ReferenceBackend against independent oracles of the
//! `python/compile/kernels/ref.py` semantics.
//!
//! Unlike `runtime_artifacts.rs`, nothing here needs `artifacts/` — this
//! suite is the tier-1 guarantee that serving works on a fresh clone
//! with no Python and no network.

use std::path::{Path, PathBuf};

use vstpu::coordinator::{Coordinator, CoordinatorConfig, InferenceRequest, MODEL_INPUT, MODEL_OUTPUT};
use vstpu::runtime::{
    backend_for, parse_manifest_tsv, Backend, Engine, ReferenceBackend, Tensor,
};
use vstpu::tech::Technology;
use vstpu::util::SplitMix64;
use vstpu::workload::{Batch, FluctuationProfile, Stream};
use vstpu::Error;

const BATCH: usize = 32;

/// Independent oracle for the systolic matmul (`ref.matmul_ref`).
fn matmul_oracle(x: &[i8], w: &[i8], m: usize, k: usize, n: usize) -> Vec<i32> {
    let mut out = vec![0i32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i32;
            for kk in 0..k {
                acc += x[i * k + kk] as i32 * w[kk * n + j] as i32;
            }
            out[i * n + j] = acc;
        }
    }
    out
}

// ------------------------------------------------- manifest.tsv parsing

#[test]
fn manifest_missing_columns_is_readable() {
    let err = parse_manifest_tsv("model_fwd\tin\t0\tint8").unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("line 1"), "{msg}");
    assert!(msg.contains("5 tab-separated fields"), "{msg}");
}

#[test]
fn manifest_malformed_rows_are_readable() {
    for (tsv, needle) in [
        ("m\tupward\t0\tint8\t4", "not in/out"),
        ("m\tin\t0\tint8\t4xpotato", "bad dim"),
        ("m\tin\t0\tfloat64\t4", "unsupported dtype"),
    ] {
        let err = parse_manifest_tsv(tsv).unwrap_err();
        let msg = err.to_string();
        assert!(matches!(err, Error::Artifact(_)), "{tsv}: {msg}");
        assert!(msg.contains(needle), "{tsv}: {msg}");
        assert!(msg.contains("line 1"), "{tsv}: {msg}");
    }
}

fn write_manifest(dirname: &str, tsv: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("vstpu-test-{dirname}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.tsv"), tsv).unwrap();
    dir
}

#[test]
fn engine_rejects_shape_mismatch_against_reference_contract() {
    // systolic_16 whose weight is 16x8: contraction/name mismatch.
    let dir = write_manifest(
        "shape-mismatch",
        "systolic_16\tin\t0\tint8\t32x16\n\
         systolic_16\tin\t1\tint8\t16x8\n\
         systolic_16\tout\t0\tint32\t32x8\n",
    );
    let engine = Engine::open(&dir).unwrap();
    let err = engine.load("systolic_16").unwrap_err();
    let msg = err.to_string();
    assert!(matches!(err, Error::Artifact(_)), "{msg}");
    assert!(msg.contains("systolic_16"), "{msg}");
    assert!(msg.contains("16x16"), "{msg}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn engine_rejects_dtype_mismatch_against_reference_contract() {
    // activity_16 whose output dtype is int32 instead of float32.
    let dir = write_manifest(
        "dtype-mismatch",
        "activity_16\tin\t0\tint8\t32x16\n\
         activity_16\tout\t0\tint32\t16\n",
    );
    let engine = Engine::open(&dir).unwrap();
    let err = engine.load("activity_16").unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("float32"), "{msg}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn engine_executes_a_wellformed_manifest_via_reference_kernels() {
    let dir = write_manifest(
        "wellformed",
        "systolic_16\tin\t0\tint8\t4x16\n\
         systolic_16\tin\t1\tint8\t16x16\n\
         systolic_16\tout\t0\tint32\t4x16\n",
    );
    let engine = Engine::open(&dir).unwrap();
    assert_eq!(engine.platform().to_lowercase(), "cpu");
    // Manifest row without its HLO artifact on disk: corrupt directory.
    let err = engine.load("systolic_16").unwrap_err();
    assert!(err.to_string().contains("make artifacts"), "{err}");
    std::fs::write(dir.join("systolic_16.hlo.txt"), "HloModule stub").unwrap();
    let model = engine.load("systolic_16").unwrap();
    let mut rng = SplitMix64::new(11);
    let x: Vec<i8> = (0..4 * 16).map(|_| rng.next_i8()).collect();
    let w: Vec<i8> = (0..16 * 16).map(|_| rng.next_i8()).collect();
    let out = model
        .execute(&[
            Tensor::I8(x.clone(), vec![4, 16]),
            Tensor::I8(w.clone(), vec![16, 16]),
        ])
        .unwrap();
    assert_eq!(out[0].as_i32().unwrap(), matmul_oracle(&x, &w, 4, 16, 16));
    std::fs::remove_dir_all(&dir).ok();
}

// ------------------------------------- ReferenceBackend vs ref.py semantics

#[test]
fn systolic_ops_match_oracle_bit_exactly_at_all_sizes() {
    let backend = ReferenceBackend::new(BATCH);
    let mut rng = SplitMix64::new(7);
    for s in [16usize, 32, 64] {
        let model = backend.load(&format!("systolic_{s}")).unwrap();
        let x: Vec<i8> = (0..BATCH * s).map(|_| rng.next_i8()).collect();
        let w: Vec<i8> = (0..s * s).map(|_| rng.next_i8()).collect();
        let out = model
            .execute(&[
                Tensor::I8(x.clone(), vec![BATCH, s]),
                Tensor::I8(w.clone(), vec![s, s]),
            ])
            .unwrap();
        assert_eq!(
            out[0].as_i32().unwrap(),
            matmul_oracle(&x, &w, BATCH, s, s).as_slice(),
            "size {s}"
        );
    }
}

#[test]
fn activity_ops_match_the_workload_oracle() {
    // ref.py: rate = popcount(prev ^ curr) summed over transitions,
    // normalised by (T-1)*8 — exactly Stream::toggle_rates.
    let backend = ReferenceBackend::new(BATCH);
    for s in [16usize, 32, 64] {
        let model = backend.load(&format!("activity_{s}")).unwrap();
        let stream = Stream::synthetic(BATCH, s, FluctuationProfile::Medium, 42 + s as u64);
        let out = model
            .execute(&[Tensor::I8(stream.data.clone(), vec![BATCH, s])])
            .unwrap();
        let got = out[0].as_f32().unwrap();
        let want = stream.toggle_rates();
        assert_eq!(got.len(), s);
        for (lane, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!(
                (*g as f64 - w).abs() < 1e-6,
                "size {s} lane {lane}: backend {g} oracle {w}"
            );
        }
    }
}

#[test]
fn model_fwd_shapes_telemetry_and_determinism() {
    let backend = ReferenceBackend::new(BATCH);
    let model = backend.load("model_fwd").unwrap();
    let data = Batch::synthetic(BATCH, MODEL_INPUT, FluctuationProfile::High, 3);
    let input = Tensor::I8(data.inputs.clone(), vec![BATCH, MODEL_INPUT]);
    let out = model.execute(&[input.clone()]).unwrap();
    assert_eq!(out.len(), 4); // logits + 3 toggle vectors
    assert_eq!(out[0].shape(), &[BATCH, MODEL_OUTPUT]);
    let logits = out[0].as_f32().unwrap();
    assert!(logits.iter().all(|x| x.is_finite()));
    for (t, width) in out[1..].iter().zip([784usize, 128, 64]) {
        assert_eq!(t.shape(), &[width]);
        let rates = t.as_f32().unwrap();
        assert!(rates.iter().all(|r| (0.0..=1.0).contains(r)));
    }
    // High-fluctuation input: first-layer toggle rate must be high.
    let l0 = out[1].as_f32().unwrap();
    let mean: f32 = l0.iter().sum::<f32>() / l0.len() as f32;
    assert!(mean > 0.3, "layer-0 toggle mean {mean}");
    // Layer-0 telemetry is by definition the input stream's activity.
    let want = Stream {
        width: MODEL_INPUT,
        data: data.inputs.clone(),
    }
    .toggle_rates();
    for (lane, (g, w)) in l0.iter().zip(&want).enumerate() {
        assert!((*g as f64 - w).abs() < 1e-6, "lane {lane}");
    }
    // Deterministic across calls.
    let again = model.execute(&[input]).unwrap();
    assert_eq!(out[0].as_f32().unwrap(), again[0].as_f32().unwrap());
}

#[test]
fn model_logits_vary_across_inputs() {
    // Random-but-realistic weights: different samples must produce
    // different logits (the model is not degenerate).
    let backend = ReferenceBackend::new(2);
    let model = backend.load("model_fwd").unwrap();
    let a = Batch::synthetic(2, MODEL_INPUT, FluctuationProfile::High, 1);
    let out = model
        .execute(&[Tensor::I8(a.inputs.clone(), vec![2, MODEL_INPUT])])
        .unwrap();
    let logits = out[0].as_f32().unwrap();
    let (r0, r1) = (&logits[..MODEL_OUTPUT], &logits[MODEL_OUTPUT..]);
    assert_ne!(r0, r1, "two different samples mapped to identical logits");
    assert!(r0.iter().any(|&v| v != 0.0), "degenerate all-zero logits");
}

// ---------------------------------------- coordinator, zero artifacts

fn reqs_from(data: &Batch, start: usize, n: usize) -> Vec<InferenceRequest> {
    (0..n)
        .map(|i| InferenceRequest {
            id: (start + i) as u64,
            input: data.sample(start + i).to_vec(),
        })
        .collect()
}

#[test]
fn coordinator_serves_end_to_end_without_artifacts() {
    let mut cfg = CoordinatorConfig::paper_default(Technology::artix7_28nm());
    cfg.voltage_epoch = 2;
    // A directory that cannot exist: open() must fall back cleanly.
    let mut coord = Coordinator::open(Path::new("/nonexistent-vstpu-artifacts"), cfg).unwrap();
    assert_eq!(coord.backend, "reference");
    let data = Batch::synthetic(96, MODEL_INPUT, FluctuationProfile::Medium, 11);
    for b in 0..3 {
        let resp = coord.infer_batch(&reqs_from(&data, b * 32, 32)).unwrap();
        assert_eq!(resp.len(), 32);
        for r in resp {
            assert_eq!(r.logits.len(), MODEL_OUTPUT);
            assert!(!r.corrupted, "guard-band rails must not corrupt");
        }
    }
    let snap = coord.snapshot();
    assert_eq!(snap.requests, 96);
    assert_eq!(snap.batches, 3);
    assert!(snap.power_mw > 0.0);
    // Telemetry moved away from the DEFAULT_TOGGLE prior.
    let mean_toggle: f64 = snap.row_toggle.iter().sum::<f64>() / snap.row_toggle.len() as f64;
    assert!((mean_toggle - 0.125).abs() > 1e-3, "telemetry never updated");
    // Rails stay inside the guard band the static scheme seeded.
    for v in &snap.rails {
        assert!(*v >= 0.95 - 1e-9 && *v <= 1.0 + 1e-9, "rail {v}");
    }
}

#[test]
fn coordinator_reference_constructor_ignores_artifacts() {
    let cfg = CoordinatorConfig::paper_default(Technology::artix7_28nm());
    let coord = Coordinator::reference(cfg).unwrap();
    assert_eq!(coord.backend, "reference");
}

#[test]
fn undervolt_corrupts_and_recovery_restores_without_artifacts() {
    let mut cfg = CoordinatorConfig::paper_default(Technology::artix7_28nm());
    cfg.voltage_epoch = usize::MAX;
    let mut coord = Coordinator::reference(cfg).unwrap();
    let data = Batch::synthetic(32, MODEL_INPUT, FluctuationProfile::High, 13);
    let reqs = reqs_from(&data, 0, 32);

    let golden = coord.infer_batch(&reqs).unwrap();
    assert!(golden.iter().all(|r| !r.corrupted));

    coord.controller.set_rails(0.70);
    let broken = coord.infer_batch(&reqs).unwrap();
    assert!(broken.iter().all(|r| r.corrupted));
    let differs = broken
        .iter()
        .zip(&golden)
        .filter(|(b, g)| b.logits != g.logits)
        .count();
    assert!(differs > 0, "corruption must change logits");

    coord.controller.set_rails(1.00);
    let recovered = coord.infer_batch(&reqs).unwrap();
    assert!(recovered.iter().all(|r| !r.corrupted));
    for (r, g) in recovered.iter().zip(&golden) {
        assert_eq!(r.logits, g.logits);
    }
}

#[test]
fn backend_for_uses_engine_when_manifest_present() {
    let dir = write_manifest(
        "backend-pick",
        "activity_16\tin\t0\tint8\t32x16\n\
         activity_16\tout\t0\tfloat32\t16\n",
    );
    let b = backend_for(&dir, BATCH).unwrap();
    assert_eq!(b.platform_name(), "cpu");
    assert_eq!(b.names(), vec!["activity_16".to_string()]);
    std::fs::remove_dir_all(&dir).ok();
}
