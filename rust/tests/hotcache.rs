//! Integration tests for the S21 hot-path cache: the determinism
//! contract (cached byte-identical to uncached across the whole smoke
//! grid), the check gate (zero new diagnostics with the cache on), the
//! key discipline (a changed workload shift is a miss) and the
//! `bench-hotpath` harness counters.
//!
//! The cache is process-global, so every test that touches its enabled
//! flag or counters serializes on one static mutex — the test harness
//! runs this binary's tests on multiple threads.

use std::path::Path;
use std::sync::{Mutex, MutexGuard, OnceLock};

use vstpu::hotcache::{self, bench::run_hotpath_bench, bench::HotpathConfig};
use vstpu::recover::RecoveryPolicy;
use vstpu::report::{bench_hotpath_json, bench_sweep_json, check_json};
use vstpu::sweep::{
    self, pool, run_sweep, MemoryRailMode, RailMode, Scenario, SweepAlgo, SweepConfig,
};
use vstpu::tech::Technology;

/// Serialize tests that flip the process-global cache state.
fn lock_cache() -> MutexGuard<'static, ()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
        .lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Drop the measurement lines (`*_ms`, `speedup`) — everything else in
/// the bench artifacts is part of the determinism contract.
fn strip_measurements(json: &str) -> String {
    json.lines()
        .filter(|l| !(l.contains("_ms\"") || l.contains("\"speedup\"")))
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn cached_sweep_is_byte_identical_to_uncached_across_the_smoke_grid() {
    let _g = lock_cache();
    let cfg = SweepConfig::smoke();

    hotcache::set_enabled(false);
    hotcache::reset();
    let uncached = run_sweep(&cfg).unwrap();

    hotcache::set_enabled(true);
    hotcache::reset();
    let cold = run_sweep(&cfg).unwrap(); // every lookup misses
    let warm = run_sweep(&cfg).unwrap(); // every lookup hits
    let stats = hotcache::stats();
    hotcache::set_enabled(true);

    assert_eq!(uncached.failed_count, 0, "smoke grid must be all-green");
    assert_eq!(uncached.scenarios.len(), 16);
    let want = strip_measurements(&bench_sweep_json(&uncached));
    assert_eq!(
        want,
        strip_measurements(&bench_sweep_json(&cold)),
        "cold cached run must be byte-identical to the uncached run"
    );
    assert_eq!(
        want,
        strip_measurements(&bench_sweep_json(&warm)),
        "warm cached run must be byte-identical to the uncached run"
    );
    // 2 (tech, size) pairs and 16 scenario configurations (the recovery
    // policy is part of the configuration key): the cold run misses each
    // once, the warm run hits each once.
    assert_eq!(stats.sta_hits, 2, "{stats:?}");
    assert_eq!(stats.sta_misses, 2, "{stats:?}");
    assert_eq!(stats.configuration_hits, 16, "{stats:?}");
    assert_eq!(stats.configuration_misses, 16, "{stats:?}");
    assert_eq!(stats.sta_entries, 2, "{stats:?}");
    assert_eq!(stats.configuration_entries, 16, "{stats:?}");
}

#[test]
fn check_smoke_sees_zero_new_diagnostics_with_the_cache_on() {
    let _g = lock_cache();
    let no_artifacts = Path::new("/nonexistent-vstpu-artifacts");

    hotcache::set_enabled(false);
    hotcache::reset();
    let uncached = vstpu::check::smoke_report(no_artifacts).unwrap();

    hotcache::set_enabled(true);
    hotcache::reset();
    let cold = vstpu::check::smoke_report(no_artifacts).unwrap();
    let warm = vstpu::check::smoke_report(no_artifacts).unwrap();

    assert_eq!(uncached.errors(), 0, "{}", uncached.error_summary());
    assert_eq!(uncached.warnings(), 0, "{:?}", uncached.diagnostics);
    // CHECK_report.json carries no wall-clock fields: full-byte compare.
    let want = check_json(&uncached);
    assert_eq!(want, check_json(&cold));
    assert_eq!(want, check_json(&warm));
}

/// Smoke-grid scenario literal (the key tests vary one axis at a time).
fn scenario(index: usize, shift_toggle: f64, seed: u64) -> Scenario {
    Scenario {
        index,
        algo: SweepAlgo::Dbscan,
        tech: "academic-22nm".into(),
        array_size: 16,
        shift_toggle,
        rail_mode: RailMode::Runtime,
        policy: RecoveryPolicy::None,
        memory_rail: MemoryRailMode::Nominal,
        seed,
    }
}

#[test]
fn changed_workload_shift_is_a_cache_miss() {
    let _g = lock_cache();
    hotcache::set_enabled(true);
    hotcache::reset();
    let cfg = SweepConfig::smoke();
    let tech = Technology::by_name("academic-22nm").unwrap();
    let st = sweep::shared_timing(&tech, 16, cfg.clock_mhz, cfg.seed);

    let sc_a = scenario(0, 0.45, 99);
    let sc_b = scenario(0, 0.25, 99); // same cell, shifted workload
    let sc_c = scenario(17, 0.45, 99); // position in the grid is not identity
    assert_ne!(
        sweep::substrate_key(&sc_a, &st, &cfg),
        sweep::substrate_key(&sc_b, &st, &cfg),
        "workload shift must be part of the configuration key"
    );
    assert_eq!(
        sweep::substrate_key(&sc_a, &st, &cfg),
        sweep::substrate_key(&sc_c, &st, &cfg),
        "the scenario index must not be part of the configuration key"
    );
    let mut sc_d = scenario(0, 0.45, 99);
    sc_d.policy = RecoveryPolicy::TeDrop;
    assert_ne!(
        sweep::substrate_key(&sc_a, &st, &cfg),
        sweep::substrate_key(&sc_d, &st, &cfg),
        "the recovery policy co-optimizes rails, so it must key the cache"
    );
    let mut sc_e = scenario(0, 0.45, 99);
    sc_e.memory_rail = MemoryRailMode::Split;
    assert_eq!(
        sweep::substrate_key(&sc_a, &st, &cfg),
        sweep::substrate_key(&sc_e, &st, &cfg),
        "the memory arm is layered downstream of the logic substrate, \
         so it must not key the cache"
    );

    hotcache::reset_stats();
    let mut arena = pool::Arena::new();
    sweep::scenario_substrate(&sc_a, &st, &cfg, &mut arena).unwrap();
    sweep::scenario_substrate(&sc_b, &st, &cfg, &mut arena).unwrap();
    let s = hotcache::stats();
    assert_eq!((s.configuration_hits, s.configuration_misses), (0, 2));
    sweep::scenario_substrate(&sc_a, &st, &cfg, &mut arena).unwrap();
    let s = hotcache::stats();
    assert_eq!((s.configuration_hits, s.configuration_misses), (1, 2));
}

#[test]
fn hotpath_bench_counters_and_artifact_are_deterministic() {
    let _g = lock_cache();
    hotcache::set_enabled(true);
    let cfg = HotpathConfig::smoke();
    let a = run_hotpath_bench(&cfg).unwrap();
    let b = run_hotpath_bench(&cfg).unwrap();

    assert_eq!(a.scenarios, 16);
    assert_eq!(a.unique_sta_pairs, 2);
    assert_eq!(a.threads, 1);
    let names: Vec<&str> = a.stages.iter().map(|s| s.stage).collect();
    assert_eq!(names, ["sta", "configuration", "sweep"]);
    // The lookup sequence is fixed by the grid: populate (2 + 16 misses),
    // then three cached stages (2 + 16 + 2 + 16 hits).
    assert_eq!(a.cache.sta_hits, 4, "{:?}", a.cache);
    assert_eq!(a.cache.sta_misses, 2, "{:?}", a.cache);
    assert_eq!(a.cache.configuration_hits, 32, "{:?}", a.cache);
    assert_eq!(a.cache.configuration_misses, 16, "{:?}", a.cache);
    assert!(a.speedup.is_finite() && a.speedup > 0.0);
    assert!(hotcache::enabled(), "bench must restore the enabled flag");

    // Everything but the measurements — counters included — is
    // byte-identical across runs; every measurement sits alone on its
    // own line so consumers can strip them.
    let ja = bench_hotpath_json(&a);
    for line in ja
        .lines()
        .filter(|l| l.contains("_ms\"") || l.contains("\"speedup\""))
    {
        assert_eq!(line.matches('"').count(), 2, "measurement shares a line: {line}");
    }
    assert_eq!(strip_measurements(&ja), strip_measurements(&bench_hotpath_json(&b)));
}
