//! Closed-loop calibration integration tests: floor convergence on the
//! guard-band-clamped commercial tech, energy-per-request improvement on
//! the VTR nodes, the byte-determinism contract of
//! `BENCH_calibrate.json`, and the live sharded-engine attachment.

use std::path::Path;

use vstpu::calibrate::{run_calibrate, CalibrateBenchConfig};
use vstpu::report::bench_calibrate_json;
use vstpu::serve::{run_bench, BenchConfig};
use vstpu::tech::Technology;

const NO_ARTIFACTS: &str = "/nonexistent-vstpu-artifacts";

/// A short but convergent run: one-batch epochs and a coarser step so
/// the trajectory settles well inside 2048 requests.
fn fast_cfg(tech: Technology) -> CalibrateBenchConfig {
    let mut cfg = CalibrateBenchConfig::quick(tech);
    cfg.requests = 2048;
    cfg.controller.epoch_batches = 1;
    cfg.controller.step_v = 0.025;
    cfg
}

/// Drop the wall-time measurement line — everything else in
/// `BENCH_calibrate.json` is part of the determinism contract.
fn strip_wall(json: &str) -> String {
    json.lines()
        .filter(|l| !l.contains("\"wall_s\""))
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn commercial_tech_converges_to_the_guard_band_floor_and_stays() {
    // On Artix-7 the frontier sits far below the vendor guard band, so
    // the flag rate is pinned at zero: every rail must walk down to the
    // FlowKind-aware floor (v_min — never past the guard band) and hold.
    let tech = Technology::artix7_28nm();
    let v_min = tech.v_min;
    let rep = run_calibrate(Path::new(NO_ARTIFACTS), fast_cfg(tech)).unwrap();
    assert!((rep.v_floor - v_min).abs() < 1e-12, "Vivado floor must be v_min");
    assert!(rep.converged, "quiet run must converge (epoch {})", rep.convergence_epoch);
    assert_eq!(rep.flag_rate_final, 0.0);
    for p in &rep.partitions {
        // The clamp is absolute: no rail ever leaves the guard band.
        for (e, &v) in p.voltages.iter().enumerate() {
            assert!(
                v >= v_min - 1e-12,
                "partition {} epoch {e}: rail {v} crossed the guard band",
                p.partition
            );
        }
        let last = *p.voltages.last().unwrap();
        assert!(
            (last - v_min).abs() < 1e-12,
            "partition {} settled at {last}, not the floor {v_min}",
            p.partition
        );
        // Once at the floor it never moves again.
        for &v in &p.voltages[p.converged_epoch..] {
            assert!((v - last).abs() < 1e-12);
        }
    }
    // Descending from the static rails to the floor saves energy even
    // inside the guard band.
    assert!(rep.energy_uj_after < rep.energy_uj_before);
}

#[test]
fn vtr_nodes_cut_energy_per_request_below_the_static_baseline() {
    for tech in [Technology::academic_22nm(), Technology::academic_45nm()] {
        let name = tech.name.clone();
        let high_water = 0.5;
        let rep = run_calibrate(Path::new(NO_ARTIFACTS), fast_cfg(tech)).unwrap();
        assert!(rep.converged, "{name}: no convergence by epoch {}", rep.convergence_epoch);
        assert!(
            rep.energy_uj_after < rep.energy_uj_before,
            "{name}: energy/request {} did not drop below the static baseline {}",
            rep.energy_uj_after,
            rep.energy_uj_before
        );
        assert!(
            rep.flag_rate_final < high_water,
            "{name}: settled flag rate {} at/above the high water",
            rep.flag_rate_final
        );
        // Every rail stayed inside the clamp the whole way.
        for p in &rep.partitions {
            for &v in &p.voltages {
                assert!(v >= rep.v_floor - 1e-12 && v <= rep.v_ceil + 1e-12);
            }
        }
    }
}

#[test]
fn calibrate_artifact_is_byte_deterministic_modulo_wall_time() {
    let run = || {
        run_calibrate(
            Path::new(NO_ARTIFACTS),
            fast_cfg(Technology::academic_22nm()),
        )
        .unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(
        strip_wall(&bench_calibrate_json(&a)),
        strip_wall(&bench_calibrate_json(&b)),
        "same seed must reproduce the exact voltage trajectory"
    );
    // A different seed changes the workload and therefore the artifact.
    let mut cfg = fast_cfg(Technology::academic_22nm());
    cfg.seed = 4242;
    let c = run_calibrate(Path::new(NO_ARTIFACTS), cfg).unwrap();
    assert_ne!(
        strip_wall(&bench_calibrate_json(&a)),
        strip_wall(&bench_calibrate_json(&c))
    );
}

#[test]
fn calibrate_rejects_bad_configs() {
    let mut cfg = fast_cfg(Technology::artix7_28nm());
    cfg.shards = 0;
    assert!(run_calibrate(Path::new(NO_ARTIFACTS), cfg).is_err());
    let mut cfg = fast_cfg(Technology::artix7_28nm());
    cfg.max_batch = cfg.coordinator.batch + 1;
    assert!(run_calibrate(Path::new(NO_ARTIFACTS), cfg).is_err());
    let mut cfg = fast_cfg(Technology::artix7_28nm());
    cfg.controller.low_water = 0.9; // above high_water
    assert!(run_calibrate(Path::new(NO_ARTIFACTS), cfg).is_err());
}

#[test]
fn sharded_engine_runs_the_calibrator_live() {
    // The live path: EngineConfig.calibrate attaches the controller to
    // every shard; the shard reports carry the trajectory out.
    use std::sync::mpsc;
    use vstpu::coordinator::{InferenceRequest, MODEL_INPUT};
    use vstpu::serve::{EngineConfig, ShardedEngine};

    let mut cfg = EngineConfig::paper_default(Technology::artix7_28nm());
    cfg.shards = 2;
    cfg.max_batch = 8;
    cfg.batch_deadline_us = 60_000_000; // size trigger only
    cfg.calibrate = Some(vstpu::calibrate::CalibrateConfig {
        epoch_batches: 2,
        ..Default::default()
    });
    let engine = ShardedEngine::start(Path::new(NO_ARTIFACTS), cfg).unwrap();
    let (tx, rx) = mpsc::channel();
    for id in 0..128u64 {
        let req = InferenceRequest {
            id,
            input: vec![1i8; MODEL_INPUT],
        };
        engine.submit(req, tx.clone()).unwrap();
    }
    drop(tx);
    let reports = engine.shutdown().unwrap();
    let mut replies = 0;
    while rx.recv().is_ok() {
        replies += 1;
    }
    assert_eq!(replies, 128);

    let v_min = Technology::artix7_28nm().v_min;
    for rep in &reports {
        // Each shard's report carries its calibrator trajectory.
        let cal = rep.calibration.as_ref().expect("calibrator in report");
        assert!(cal.epochs() > 0, "shard {} took no epochs", rep.shard);
        assert_eq!(cal.voltage_trace().len(), cal.epochs() + 1);
        // Quiet guard-band workload: owned rails descend, and the clamp
        // never lets any rail leave the guard band.
        for snap in cal.voltage_trace() {
            for &v in snap {
                assert!(v >= v_min - 1e-12, "live calibrator crossed the guard band");
            }
        }
    }

    // And the bench wrapper reports the flag in its artifact.
    let mut bcfg = BenchConfig::quick(Technology::artix7_28nm());
    bcfg.requests = 64;
    bcfg.engine.shards = 2;
    bcfg.engine.max_batch = 8;
    bcfg.engine.calibrate = Some(vstpu::calibrate::CalibrateConfig::default());
    let brep = run_bench(Path::new(NO_ARTIFACTS), bcfg).unwrap();
    assert!(brep.calibration_enabled);
    let json = vstpu::report::bench_serve_json(&brep);
    assert!(json.contains("\"calibration_enabled\": true"));
}
