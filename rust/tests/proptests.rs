//! Property-based tests over randomized inputs.
//!
//! The build is fully vendored (no proptest crate), so properties are
//! driven by the in-crate SplitMix64 generator: each property runs
//! against `CASES` random instances with recorded seeds — a failure
//! message always carries the seed, so shrink-by-hand is one rerun away.

use vstpu::cluster::{dbscan, hierarchical, kmeans, meanshift, Algorithm, NOISE};
use vstpu::fpga::{validate_partitions, Device};
use vstpu::netlist::SystolicNetlist;
use vstpu::razor::{effective_delay_ns, min_safe_voltage, RazorConfig};
use vstpu::tech::Technology;
use vstpu::timing::{self, CLOCK_UNCERTAINTY_NS};
use vstpu::util::SplitMix64;
use vstpu::voltage::{runtime_scheme, static_scheme};
use vstpu::workload::{FluctuationProfile, Stream};

const CASES: u64 = 40;

/// Random 1-D dataset: a few gaussian-ish blobs plus uniform noise.
fn random_data(rng: &mut SplitMix64) -> Vec<f64> {
    let n_blobs = 1 + rng.below(4) as usize;
    let n = 20 + rng.below(180) as usize;
    let mut data = Vec::with_capacity(n);
    let centers: Vec<f64> = (0..n_blobs).map(|_| rng.range_f64(0.0, 20.0)).collect();
    for i in 0..n {
        let c = centers[i % n_blobs];
        data.push(c + rng.gauss() * 0.3);
    }
    data
}

// ------------------------------------------------------------ clustering

#[test]
fn prop_all_algorithms_produce_valid_labelings() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed);
        let data = random_data(&mut rng);
        let k = 1 + rng.below(4.min(data.len() as u64)) as usize;
        let algos = [
            Algorithm::Hierarchical { k },
            Algorithm::KMeans { k, seed },
            Algorithm::MeanShift {
                bandwidth: rng.range_f64(0.1, 3.0),
            },
            Algorithm::Dbscan {
                eps: rng.range_f64(0.05, 1.0),
                min_points: 1 + rng.below(5) as usize,
            },
        ];
        for algo in algos {
            let c = algo.run(&data).unwrap();
            assert_eq!(c.labels.len(), data.len(), "seed {seed} {}", algo.name());
            for &l in &c.labels {
                assert!(l < c.k || l == NOISE, "seed {seed} {}: label {l}", algo.name());
            }
            // Canonical order: centroids ascending.
            let cents = c.centroids(&data);
            for w in cents.windows(2) {
                assert!(
                    w[0] <= w[1] + 1e-9 || w[0].is_nan() || w[1].is_nan(),
                    "seed {seed} {}: centroids {cents:?}",
                    algo.name()
                );
            }
        }
    }
}

#[test]
fn prop_hierarchical_cut_is_a_partition_of_n() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed + 1000);
        let data = random_data(&mut rng);
        let d = hierarchical::dendrogram(&data);
        for k in [1usize, 2, 3, data.len().min(7)] {
            let c = d.cut(k).unwrap();
            assert_eq!(c.sizes().iter().sum::<usize>(), data.len(), "seed {seed} k {k}");
            assert_eq!(c.k, k);
        }
    }
}

#[test]
fn prop_kmeans_inertia_nonincreasing_in_k() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed + 2000);
        let data = random_data(&mut rng);
        if data.len() < 6 {
            continue;
        }
        let i2 = kmeans::inertia(&data, &kmeans::cluster(&data, 2, seed).unwrap());
        let i5 = kmeans::inertia(&data, &kmeans::cluster(&data, 5, seed).unwrap());
        // k-means++ with Lloyd is near-monotone; tiny epsilon for local
        // minima wobble on adversarial blobs.
        assert!(i5 <= i2 * 1.05 + 1e-9, "seed {seed}: i2={i2} i5={i5}");
    }
}

#[test]
fn prop_dbscan_core_points_never_noise() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed + 3000);
        let data = random_data(&mut rng);
        let eps = rng.range_f64(0.05, 0.5);
        let min_points = 1 + rng.below(4) as usize;
        let c = dbscan::cluster(&data, eps, min_points).unwrap();
        for (i, &x) in data.iter().enumerate() {
            let neighbours = data.iter().filter(|&&y| (x - y).abs() <= eps).count();
            if neighbours >= min_points {
                assert_ne!(
                    c.labels[i], NOISE,
                    "seed {seed}: core point {i} marked noise"
                );
            }
        }
    }
}

#[test]
fn prop_meanshift_k_monotone_in_bandwidth() {
    // Larger bandwidth can only merge modes, never split them.
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed + 4000);
        let data = random_data(&mut rng);
        let small = meanshift::cluster(&data, 0.2).unwrap().k;
        let large = meanshift::cluster(&data, 5.0).unwrap().k;
        assert!(large <= small, "seed {seed}: k({large}) > k({small})");
    }
}

// ------------------------------------------------------- voltage schemes

#[test]
fn prop_static_voltages_stay_inside_region_and_ascend() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed + 5000);
        let v_crash = rng.range_f64(0.5, 0.9);
        let v_min = v_crash + rng.range_f64(0.01, 0.3);
        let n = 1 + rng.below(9) as usize;
        let v = static_scheme::stepping_voltages(v_min, v_crash, n).unwrap();
        assert_eq!(v.len(), n);
        for w in v.windows(2) {
            assert!(w[0] < w[1], "seed {seed}: {v:?}");
        }
        assert!(v[0] > v_crash && *v.last().unwrap() < v_min, "seed {seed}");
        // Midpoint identity: mean of rails == centre of the region.
        let mean: f64 = v.iter().sum::<f64>() / n as f64;
        assert!((mean - (v_crash + v_min) / 2.0).abs() < 1e-9, "seed {seed}");
    }
}

#[test]
fn prop_algorithm2_step_moves_every_rail_by_vs() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed + 6000);
        let n = 1 + rng.below(8) as usize;
        let vs = rng.range_f64(0.005, 0.05);
        let mut rails: Vec<f64> = (0..n).map(|_| rng.range_f64(0.6, 1.0)).collect();
        let flags: Vec<bool> = (0..n).map(|_| rng.next_f64() < 0.5).collect();
        let before = rails.clone();
        runtime_scheme::step(&mut rails, &flags, vs, 0.0, 2.0);
        for i in 0..n {
            let want = if flags[i] { before[i] + vs } else { before[i] - vs };
            assert!((rails[i] - want).abs() < 1e-12, "seed {seed} rail {i}");
        }
    }
}

// ------------------------------------------------------ timing + razor

#[test]
fn prop_slack_identity_holds_for_every_path() {
    for seed in 0..5 {
        let tech = Technology::artix7_28nm();
        let nl = SystolicNetlist::generate(16, &tech, 100.0, seed);
        let rep = timing::synthesize(&nl);
        for p in rep.worst_setup(500) {
            let identity = p.slack_ns + CLOCK_UNCERTAINTY_NS + p.total_delay_ns;
            assert!((identity - p.requirement_ns).abs() < 1e-9, "seed {seed}");
            assert!(
                (p.total_delay_ns - p.logic_delay_ns - p.net_delay_ns).abs() < 1e-9,
                "seed {seed}"
            );
        }
    }
}

#[test]
fn prop_effective_delay_monotonicity() {
    let tech = Technology::academic_22nm();
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed + 7000);
        let d = rng.range_f64(1.0, 8.0);
        let v1 = rng.range_f64(tech.v_th + 0.05, 1.0);
        let v2 = rng.range_f64(tech.v_th + 0.05, 1.0);
        let t1 = rng.next_f64();
        let t2 = rng.next_f64();
        let (vlo, vhi) = if v1 < v2 { (v1, v2) } else { (v2, v1) };
        let (tlo, thi) = if t1 < t2 { (t1, t2) } else { (t2, t1) };
        // Lower voltage => longer delay; higher toggle => longer delay.
        assert!(
            effective_delay_ns(&tech, d, vlo, 0.5) >= effective_delay_ns(&tech, d, vhi, 0.5),
            "seed {seed}"
        );
        assert!(
            effective_delay_ns(&tech, d, 0.8, thi) >= effective_delay_ns(&tech, d, 0.8, tlo),
            "seed {seed}"
        );
    }
}

#[test]
fn prop_min_safe_voltage_is_sound_and_tight() {
    let tech = Technology::artix7_28nm();
    let nl = SystolicNetlist::generate(8, &tech, 100.0, 3);
    let razor = RazorConfig::default();
    let macs: Vec<_> = nl.macs().collect();
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed + 8000);
        let toggle = rng.next_f64();
        let subset: Vec<_> = macs
            .iter()
            .filter(|_| rng.next_f64() < 0.5)
            .cloned()
            .collect();
        if subset.is_empty() {
            continue;
        }
        let v = min_safe_voltage(&nl, &tech, &subset, toggle);
        let at = vstpu::razor::trial_partition(&nl, &tech, &razor, 0, &subset, v + 1e-6, |_| toggle);
        assert!(!at.timing_fail, "seed {seed}: flags at its own frontier");
        if v - 0.01 > tech.v_th + 0.02 {
            let below =
                vstpu::razor::trial_partition(&nl, &tech, &razor, 0, &subset, v - 0.01, |_| toggle);
            assert!(below.timing_fail, "seed {seed}: frontier not tight");
        }
    }
}

// ----------------------------------------------------------- floorplan

#[test]
fn prop_band_floorplans_always_validate() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed + 9000);
        let size = 8 + 2 * rng.below(9) as u32; // 8..=24 even
        let k = 2 + rng.below(5) as usize;
        let n = (size * size) as usize;
        // Random (possibly unbalanced) labeling with every cluster hit.
        let mut labels: Vec<usize> = (0..n).map(|_| rng.below(k as u64) as usize).collect();
        for (j, l) in labels.iter_mut().take(k).enumerate() {
            *l = j;
        }
        let clustering = vstpu::cluster::Clustering { labels, k };
        let device = Device::for_array(size);
        let parts = vstpu::floorplan::bands(&device, &clustering, size).unwrap();
        validate_partitions(&device, &parts).unwrap();
        assert_eq!(
            parts.iter().map(|p| p.mac_count()).sum::<usize>(),
            n,
            "seed {seed}"
        );
    }
}

// ------------------------------------------------------------ workload

#[test]
fn prop_toggle_rates_always_in_unit_interval() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed + 10_000);
        let rows = 2 + rng.below(120) as usize;
        let width = 1 + rng.below(64) as usize;
        let profile = match rng.below(3) {
            0 => FluctuationProfile::Low,
            1 => FluctuationProfile::Medium,
            _ => FluctuationProfile::High,
        };
        let s = Stream::synthetic(rows, width, profile, seed);
        for (i, r) in s.toggle_rates().iter().enumerate() {
            assert!((0.0..=1.0).contains(r), "seed {seed} lane {i}: {r}");
        }
    }
}

// ----------------------------------------------------------- manifest

#[test]
fn prop_manifest_roundtrip_random_signatures() {
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed + 11_000);
        let n_art = 1 + rng.below(5) as usize;
        let mut tsv = String::new();
        let mut want: Vec<(String, usize, usize)> = Vec::new();
        for a in 0..n_art {
            let name = format!("art{a}");
            let ins = 1 + rng.below(3) as usize;
            let outs = 1 + rng.below(4) as usize;
            for i in 0..ins {
                tsv.push_str(&format!("{name}\tin\t{i}\tint8\t{}x{}\n", 1 + a, 2 + i));
            }
            for o in 0..outs {
                tsv.push_str(&format!("{name}\tout\t{o}\tfloat32\t{}\n", 3 + o));
            }
            want.push((name, ins, outs));
        }
        let m = vstpu::runtime::parse_manifest_tsv(&tsv).unwrap();
        for (name, ins, outs) in want {
            let sig = &m[&name];
            assert_eq!(sig.inputs.len(), ins, "seed {seed}");
            assert_eq!(sig.outputs.len(), outs, "seed {seed}");
        }
    }
}

// --------------------------------------------------------------- prove

#[test]
fn prop_random_telemetry_never_violates_a_certified_property() {
    use vstpu::calibrate::{CalibrateConfig, Calibrator};
    use vstpu::fpga::{Partition, Rect};
    use vstpu::recover::{RecoverConfig, RecoveryPolicy, SILENT_TOL};

    let tech = Technology::academic_22nm();
    for policy in RecoveryPolicy::all() {
        let cfg = CalibrateConfig {
            recover: RecoverConfig {
                policy,
                accuracy_budget: 0.05,
            },
            ..Default::default()
        };
        let case = vstpu::prove::certify_config(&cfg, &tech).unwrap();
        assert!(
            case.certified,
            "{policy:?} must certify: {}",
            case.failure_summary()
        );
        let (v_floor, v_ceil) = (case.v_floor, case.v_ceil);
        let mut resolved = cfg.clone();
        resolved.step_v = cfg.resolved_step(&tech);

        for seed in 0..CASES {
            let mut rng = SplitMix64::new(seed + 12_000);
            let mut parts = vec![Partition {
                id: 0,
                rect: Rect::new(0, 0, 3, 3),
                macs: vec![],
                vccint: v_ceil,
            }];
            let mut cal = Calibrator::new(resolved.clone(), v_floor, v_ceil, &[v_ceil]);
            let epochs = 20 + rng.below(60) as usize;
            let mut locked_before = Vec::with_capacity(epochs);
            for _ in 0..epochs {
                locked_before.push(cal.is_locked(0));
                if policy.recovers() {
                    // Random (flagged, silent) evidence, biased to land
                    // on both sides of the hysteresis band and of the
                    // silent-corruption tolerance.
                    let f = rng.next_f64();
                    let s = match rng.below(3) {
                        0 => 0.0,
                        1 => rng.range_f64(0.0, SILENT_TOL),
                        _ => rng.range_f64(0.0, 4.0 * SILENT_TOL),
                    };
                    cal.observe_batch(&[f > 0.0], &[0]);
                    cal.observe_recovery(&[f], &[s], &[0]);
                } else {
                    let b = 1 + rng.below(8) as usize;
                    let k = rng.below(b as u64 + 1) as usize;
                    for j in 0..b {
                        cal.observe_batch(&[j < k], &[0]);
                    }
                }
                cal.end_epoch(&mut parts, &[0]);
            }
            let vt: Vec<f64> = cal.voltage_trace().iter().map(|v| v[0]).collect();
            let strict_up = |e: usize| vt[e + 1] - vt[e] > 1e-15;
            let strict_down = |e: usize| vt[e] - vt[e + 1] > 1e-15;
            // PRV001: every voltage inside the clamp bounds.
            for &v in &vt {
                assert!(
                    (v_floor - 1e-9..=v_ceil + 1e-9).contains(&v),
                    "seed {seed} {policy:?}: rail {v} escaped [{v_floor}, {v_ceil}]"
                );
            }
            // PRV002: no strict step-down immediately after a step-up.
            for e in 0..vt.len().saturating_sub(2) {
                assert!(
                    !(strict_up(e) && strict_down(e + 1)),
                    "seed {seed} {policy:?}: thrash at epoch {e}"
                );
            }
            // PRV003: total strict movement within the certified bound.
            let moves = (0..vt.len() - 1)
                .filter(|&e| strict_up(e) || strict_down(e))
                .count();
            assert!(
                moves <= case.move_bound,
                "seed {seed} {policy:?}: {moves} moves exceed certified bound {}",
                case.move_bound
            );
            // PRV004: locked is absorbing — no step-down once locked.
            for e in 0..vt.len() - 1 {
                if locked_before.get(e) == Some(&true) {
                    assert!(
                        !strict_down(e),
                        "seed {seed} {policy:?}: locked rail stepped down at epoch {e}"
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------- bram

#[test]
fn prop_memory_rail_physics_never_go_negative() {
    // Any finite positive memory-rail voltage — including figure-sweep
    // points far below threshold, where the alpha-power-law delay model
    // would blow up — must price to non-negative, finite power, energy
    // and loss. This is the S24 half of the sub-`v_th` audit that made
    // `power::bram_mw` use `rail_is_finite_positive`.
    use vstpu::bram::{bit_error_rate, expected_loss, memory_power_factor, BER_CEIL};
    use vstpu::power::PowerModel;

    let suite = Technology::paper_suite();
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed + 14_000);
        let tech = suite[rng.below(suite.len() as u64) as usize].clone();
        let v_mem = rng.range_f64(0.05, 1.3);
        let words = 64 * (1 + rng.below(256)) as usize;
        let ber = bit_error_rate(&tech, v_mem);
        assert!(
            (0.0..=BER_CEIL).contains(&ber),
            "seed {seed} {} at {v_mem}: BER {ber}",
            tech.name
        );
        let loss = expected_loss(&tech, v_mem, words);
        assert!(
            loss.is_finite() && (0.0..=1.0).contains(&loss),
            "seed {seed} {} at {v_mem}: loss {loss}",
            tech.name
        );
        let factor = memory_power_factor(&tech, v_mem);
        assert!(
            factor.is_finite() && factor > 0.0,
            "seed {seed} {} at {v_mem}: factor {factor}",
            tech.name
        );
        let model = PowerModel::new(tech.clone(), 100.0);
        let mw = model.bram_mw(vstpu::bram::banks_for(words), v_mem);
        assert!(
            mw.is_finite() && mw > 0.0,
            "seed {seed} {} at {v_mem}: {mw} mW",
            tech.name
        );
        // Energy over any positive interval inherits the sign.
        let uj = mw * rng.range_f64(1e-9, 1.0) * 1e3;
        assert!(uj.is_finite() && uj > 0.0, "seed {seed}: {uj} uJ");
    }
}

#[test]
fn prop_fault_path_is_exactly_inert_at_or_above_the_knee() {
    // Mirrors the `rail_fault_v` cache-exclusion contract: with the
    // memory rail at (or anywhere above) the guard knee the whole fault
    // path is a provable no-op — empty map, zero injected flips, a
    // byte-identical accumulator — for every tech, seed and buffer.
    use vstpu::bram::{expected_loss, fault_map, inject, knee_voltage};

    let suite = Technology::paper_suite();
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed + 15_000);
        let tech = suite[rng.below(suite.len() as u64) as usize].clone();
        let knee = knee_voltage(&tech);
        let v_mem = knee + rng.range_f64(0.0, 0.35);
        let words = 64 * (1 + rng.below(256)) as usize;
        let map_seed = rng.below(u64::MAX);
        let map = fault_map(&tech, v_mem, words, map_seed);
        assert!(
            map.flips.is_empty(),
            "seed {seed} {} at {v_mem}: {} flips above the knee",
            tech.name,
            map.flips.len()
        );
        assert_eq!(expected_loss(&tech, v_mem, words), 0.0, "seed {seed}");
        let clean: Vec<i32> = (0..words).map(|_| rng.below(1 << 20) as i32 - (1 << 19)).collect();
        let mut acc = clean.clone();
        assert_eq!(inject(&map, &mut acc), 0, "seed {seed}");
        assert_eq!(acc, clean, "seed {seed}: inert path mutated the buffer");
    }
}

#[test]
fn prop_refuted_configs_carry_replaying_counterexamples() {
    use vstpu::calibrate::CalibrateConfig;
    use vstpu::recover::{RecoverConfig, RecoveryPolicy};

    let tech = Technology::academic_22nm();
    let (_, v_floor) = vstpu::study::rail_bounds(&tech);
    for seed in 0..CASES {
        let mut rng = SplitMix64::new(seed + 13_000);
        // Alternate randomly between the two pathology families
        // `CalibrateConfig::validate` exists to keep out: a zero
        // cooldown (thrash) and a non-finite te-drop budget (the
        // controller can neither compare nor react to its loss).
        let mut cfg = CalibrateConfig::default();
        cfg.step_v = cfg.resolved_step(&tech);
        let expect_id = if rng.below(2) == 0 {
            cfg.cooldown_epochs = 0;
            "PRV002"
        } else {
            cfg.recover = RecoverConfig {
                policy: RecoveryPolicy::TeDrop,
                accuracy_budget: f64::NAN,
            };
            "PRV005"
        };
        let case = vstpu::prove::certify_raw(
            &cfg,
            &tech.name,
            vstpu::prove::flow_name(&tech),
            v_floor,
            tech.v_nom,
            vstpu::prove::DEFAULT_MAX_STATES,
        )
        .unwrap();
        assert!(!case.certified, "seed {seed}: pathological config certified");
        let mut violated = Vec::new();
        for p in &case.properties {
            if p.certified {
                assert!(p.counterexample.is_none(), "seed {seed} {}", p.id);
                continue;
            }
            violated.push(p.id);
            let cex = p
                .counterexample
                .as_ref()
                .expect("refuted property must carry a counterexample");
            assert!(!cex.trace.is_empty(), "seed {seed} {}: empty trace", p.id);
            assert!(
                cex.replayed,
                "seed {seed} {}: counterexample did not replay",
                p.id
            );
        }
        assert!(
            violated.contains(&expect_id),
            "seed {seed}: expected {expect_id} among {violated:?}"
        );
    }
}
