//! Sharded serving-engine integration tests: dynamic-batching edge
//! cases (deadline flush, bursts past the size trigger, more shards
//! than partitions, clean shutdown draining in-flight requests) and the
//! fixed-seed determinism contract `BENCH_serve.json` gates on.
//!
//! Everything runs on the pure-Rust reference backend (the artifacts
//! directory deliberately does not exist), so the suite is green on a
//! fresh clone with no Python and no network.

use std::path::Path;
use std::sync::mpsc;
use std::time::Duration;

use vstpu::coordinator::{CoordinatorConfig, InferenceRequest, MODEL_INPUT};
use vstpu::serve::{run_bench, BenchConfig, EngineConfig, ShardedEngine};
use vstpu::tech::Technology;
use vstpu::workload::{Batch, FluctuationProfile};

const NO_ARTIFACTS: &str = "/nonexistent-vstpu-artifacts";

fn engine_config() -> EngineConfig {
    EngineConfig::paper_default(Technology::artix7_28nm())
}

fn req(id: u64) -> InferenceRequest {
    InferenceRequest {
        id,
        input: vec![3i8; MODEL_INPUT],
    }
}

/// Collect exactly `n` replies, failing loudly on a stall.
fn recv_n(rx: &mpsc::Receiver<vstpu::coordinator::InferenceResponse>, n: usize) -> Vec<u64> {
    let mut ids = Vec::with_capacity(n);
    for _ in 0..n {
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("reply within 30s");
        ids.push(resp.id);
    }
    ids.sort_unstable();
    ids
}

#[test]
fn deadline_flushes_a_partial_batch() {
    let mut cfg = engine_config();
    cfg.shards = 1;
    cfg.max_batch = 8;
    cfg.batch_deadline_us = 100_000; // 100 ms: fires fast, tolerates CI stalls
    let engine = ShardedEngine::start(Path::new(NO_ARTIFACTS), cfg).unwrap();
    let (tx, rx) = mpsc::channel();
    for id in 0..3 {
        engine.submit(req(id), tx.clone()).unwrap();
    }
    // The size trigger (8) can never fire: only the deadline can
    // produce these replies while the engine is still accepting work.
    assert_eq!(recv_n(&rx, 3), vec![0, 1, 2]);
    let reports = engine.shutdown().unwrap();
    assert_eq!(reports.len(), 1);
    assert_eq!(reports[0].requests, 3);
    assert_eq!(reports[0].batches, 1);
    assert!((reports[0].batch_fill - 3.0 / 8.0).abs() < 1e-12);
}

#[test]
fn burst_larger_than_max_batch_splits_into_batches() {
    let mut cfg = engine_config();
    cfg.shards = 1;
    cfg.max_batch = 4;
    cfg.batch_deadline_us = 1_000_000; // only the size trigger matters
    cfg.queue_depth = 64;
    let engine = ShardedEngine::start(Path::new(NO_ARTIFACTS), cfg).unwrap();
    let (tx, rx) = mpsc::channel();
    for id in 0..11 {
        engine.submit(req(id), tx.clone()).unwrap();
    }
    drop(tx);
    let reports = engine.shutdown().unwrap();
    assert_eq!(recv_n(&rx, 11), (0..11).collect::<Vec<u64>>());
    // 11 requests at max_batch 4: two full batches plus the drain flush.
    assert_eq!(reports[0].requests, 11);
    assert_eq!(reports[0].batches, 3);
}

#[test]
fn more_shards_than_partitions_still_serves() {
    // The 16x16 paper floorplan has 4 partitions; shard them 6 ways so
    // shards 4 and 5 own no voltage island at all.
    let mut cfg = engine_config();
    cfg.shards = 6;
    cfg.max_batch = 4;
    let engine = ShardedEngine::start(Path::new(NO_ARTIFACTS), cfg).unwrap();
    let (tx, rx) = mpsc::channel();
    for id in 0..36 {
        engine.submit(req(id), tx.clone()).unwrap();
    }
    drop(tx);
    let reports = engine.shutdown().unwrap();
    assert_eq!(recv_n(&rx, 36), (0..36).collect::<Vec<u64>>());
    assert_eq!(reports.len(), 6);
    let mut owned_partitions: Vec<usize> = Vec::new();
    for (shard, rep) in reports.iter().enumerate() {
        assert_eq!(rep.shard, shard);
        assert_eq!(rep.requests, 6, "id % 6 routing sends 6 ids to each");
        owned_partitions.extend(rep.snapshot.per_partition_power_mw.iter().map(|&(i, ..)| i));
    }
    // Tail shards own nothing; the 4 partitions are covered exactly once.
    assert!(reports[4].snapshot.per_partition_power_mw.is_empty());
    assert!(reports[5].snapshot.per_partition_power_mw.is_empty());
    owned_partitions.sort_unstable();
    assert_eq!(owned_partitions, vec![0, 1, 2, 3]);
}

#[test]
fn shutdown_drains_in_flight_requests() {
    let mut cfg = engine_config();
    cfg.shards = 2;
    cfg.max_batch = 32;
    cfg.batch_deadline_us = 10_000_000; // 10 s: neither trigger can fire
    let engine = ShardedEngine::start(Path::new(NO_ARTIFACTS), cfg).unwrap();
    let (tx, rx) = mpsc::channel();
    for id in 0..10 {
        engine.submit(req(id), tx.clone()).unwrap();
    }
    drop(tx);
    // Shutdown closes the queues; the drain path must still answer
    // every queued request before the workers exit.
    let reports = engine.shutdown().unwrap();
    assert_eq!(recv_n(&rx, 10), (0..10).collect::<Vec<u64>>());
    assert_eq!(reports.iter().map(|r| r.requests).sum::<u64>(), 10);
    assert!(rx.recv().is_err(), "no stray replies after the drain");
}

#[test]
fn router_rejects_malformed_requests_without_killing_shards() {
    let mut cfg = engine_config();
    cfg.shards = 2;
    let engine = ShardedEngine::start(Path::new(NO_ARTIFACTS), cfg).unwrap();
    let (tx, rx) = mpsc::channel();
    let bad = InferenceRequest {
        id: 0,
        input: vec![0i8; 3],
    };
    assert!(engine.submit(bad, tx.clone()).is_err());
    assert!(engine.submit_to(9, req(1), tx.clone()).is_err());
    // The shards are still alive and serving after the rejections.
    engine.submit(req(2), tx.clone()).unwrap();
    drop(tx);
    let reports = engine.shutdown().unwrap();
    assert_eq!(recv_n(&rx, 1), vec![2]);
    assert_eq!(reports.iter().map(|r| r.requests).sum::<u64>(), 1);
}

#[test]
fn poisoned_shard_surfaces_as_structured_error_while_siblings_serve() {
    // Fault injection: shard 1 panics on startup. The healthy shard 0
    // must keep answering (even ids route there via id % shards), and
    // shutdown must surface the death as a structured ShardFailed that
    // names the shard — not an opaque joined-thread panic.
    let mut cfg = engine_config();
    cfg.shards = 2;
    cfg.max_batch = 1;
    cfg.poison_shard = Some(1);
    let engine = ShardedEngine::start(Path::new(NO_ARTIFACTS), cfg).unwrap();
    let (tx, rx) = mpsc::channel();
    for id in [0u64, 2, 4, 6] {
        engine.submit(req(id), tx.clone()).unwrap();
    }
    drop(tx);
    assert_eq!(recv_n(&rx, 4), vec![0, 2, 4, 6]);
    let err = engine.shutdown().expect_err("a dead shard must fail shutdown");
    let msg = err.to_string();
    assert!(
        msg.contains("shard 1 failed") && msg.contains("poisoned"),
        "error must carry the shard id and the panic message: {msg}"
    );
}

#[test]
fn responses_match_the_single_coordinator_path() {
    // The sharded engine must return exactly the logits the plain
    // coordinator computes for the same inputs (sharding changes the
    // threading, never the math).
    let data = Batch::synthetic(8, MODEL_INPUT, FluctuationProfile::Medium, 11);
    let ccfg = CoordinatorConfig::paper_default(Technology::artix7_28nm());
    let mut coord = vstpu::coordinator::Coordinator::reference(ccfg).unwrap();
    let reqs: Vec<InferenceRequest> = (0..8)
        .map(|i| InferenceRequest {
            id: i as u64,
            input: data.sample(i).to_vec(),
        })
        .collect();
    let golden = coord.infer_batch(&reqs).unwrap();

    let mut cfg = engine_config();
    cfg.shards = 2;
    cfg.max_batch = 4;
    let engine = ShardedEngine::start(Path::new(NO_ARTIFACTS), cfg).unwrap();
    let (tx, rx) = mpsc::channel();
    for r in &reqs {
        engine.submit(r.clone(), tx.clone()).unwrap();
    }
    drop(tx);
    engine.shutdown().unwrap();
    let mut got: Vec<(u64, Vec<f32>)> = Vec::new();
    while let Ok(resp) = rx.recv() {
        got.push((resp.id, resp.logits));
    }
    got.sort_by_key(|(id, _)| *id);
    assert_eq!(got.len(), 8);
    for (resp, gold) in got.iter().zip(&golden) {
        assert_eq!(resp.0, gold.id);
        assert_eq!(resp.1, gold.logits, "logits diverged for id {}", gold.id);
    }
}

#[test]
fn bench_results_are_deterministic_across_runs() {
    // The acceptance contract of BENCH_serve.json: byte-identical shard
    // result checksums (and request counts) across runs at a fixed seed.
    let bench = || {
        let mut cfg = BenchConfig::quick(Technology::artix7_28nm());
        cfg.requests = 192;
        cfg.engine.shards = 3;
        cfg.engine.max_batch = 16;
        // Size-trigger-only batching: composition is identical even on
        // a badly stalled CI runner.
        cfg.engine.batch_deadline_us = 60_000_000;
        run_bench(Path::new(NO_ARTIFACTS), cfg).unwrap()
    };
    let a = bench();
    let b = bench();
    assert_eq!(a.requests, 192);
    assert_eq!(a.requests, b.requests);
    assert_eq!(a.shards.len(), 3);
    for (sa, sb) in a.shards.iter().zip(&b.shards) {
        assert_eq!(sa.shard, sb.shard);
        assert_eq!(sa.requests, sb.requests);
        assert_eq!(
            sa.result_checksum, sb.result_checksum,
            "shard {} results diverged across identical runs",
            sa.shard
        );
    }
    // A different seed must change the results.
    let mut cfg = BenchConfig::quick(Technology::artix7_28nm());
    cfg.requests = 192;
    cfg.engine.shards = 3;
    cfg.engine.max_batch = 16;
    cfg.engine.batch_deadline_us = 60_000_000;
    cfg.seed = 8888;
    let c = run_bench(Path::new(NO_ARTIFACTS), cfg).unwrap();
    assert_ne!(a.shards[0].result_checksum, c.shards[0].result_checksum);
}

#[test]
fn bench_report_fields_are_sane() {
    let mut cfg = BenchConfig::quick(Technology::artix7_28nm());
    cfg.requests = 64;
    cfg.engine.shards = 2;
    cfg.engine.max_batch = 8;
    let rep = run_bench(Path::new(NO_ARTIFACTS), cfg).unwrap();
    assert_eq!(rep.schema, vstpu::serve::BENCH_SCHEMA);
    assert!(rep.quick);
    assert_eq!(rep.requests, 64);
    assert_eq!(rep.backend, "reference");
    assert!(rep.requests_per_s > 0.0);
    assert!(rep.p50_us > 0.0 && rep.p99_us >= rep.p50_us);
    assert!(rep.batch_fill > 0.0 && rep.batch_fill <= 1.0);
    assert!(rep.power_total_mw > rep.power_overhead_mw);
    let json = vstpu::report::bench_serve_json(&rep);
    assert!(json.contains("\"schema\": \"vstpu-bench-serve/v1\""));
    assert!(json.contains("\"result_checksum\""));
    assert!(!json.contains("NaN"));
}
