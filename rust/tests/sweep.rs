//! Integration tests for the parallel scenario-sweep subsystem:
//! determinism of the machine-readable artifact, panic/error isolation,
//! and the cross-algorithm rails-above-frontier sanity the clustering ->
//! partition path must uphold under every algorithm.

use vstpu::recover::RecoveryPolicy;
use vstpu::report::bench_sweep_json;
use vstpu::sweep::{pool, run_sweep, MemoryRailMode, RailMode, SweepAlgo, SweepConfig};

/// Drop the wall-time measurement lines — everything else in
/// `BENCH_sweep.json` is part of the determinism contract.
fn strip_wall(json: &str) -> String {
    json.lines()
        .filter(|l| !l.contains("\"wall_ms\""))
        .collect::<Vec<_>>()
        .join("\n")
}

#[test]
fn smoke_sweep_is_deterministic_modulo_wall_time() {
    let cfg = SweepConfig::smoke();
    let a = run_sweep(&cfg).unwrap();
    let b = run_sweep(&cfg).unwrap();
    assert_eq!(a.failed_count, 0, "smoke grid must be all-green");
    // 2 algos x 2 techs x 1 size x 1 shift x 2 rail modes x 2 policies.
    assert_eq!(a.scenarios.len(), 16);
    assert!(!a.winners.is_empty());
    assert_eq!(
        strip_wall(&bench_sweep_json(&a)),
        strip_wall(&bench_sweep_json(&b)),
        "same configuration must reproduce byte-identical results"
    );
}

#[test]
fn sweep_runs_single_threaded_and_parallel_identically() {
    let mut serial = SweepConfig::smoke();
    serial.threads = 1;
    let mut wide = SweepConfig::smoke();
    wide.threads = 8;
    let a = run_sweep(&serial).unwrap();
    let b = run_sweep(&wide).unwrap();
    // Scheduling must not leak into results — only the threads echo and
    // the wall-time lines may differ.
    let scrub = |json: &str| {
        strip_wall(json)
            .lines()
            .filter(|l| !l.contains("\"threads\""))
            .collect::<Vec<_>>()
            .join("\n")
    };
    assert_eq!(scrub(&bench_sweep_json(&a)), scrub(&bench_sweep_json(&b)));
}

#[test]
fn one_panicking_job_does_not_sink_the_pool() {
    let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..8usize)
        .map(|i| -> Box<dyn FnOnce() -> usize + Send> {
            if i == 3 {
                Box::new(|| panic!("scenario {} exploded", 3))
            } else {
                Box::new(move || i * 7)
            }
        })
        .collect();
    let out = pool::run_parallel(4, jobs);
    assert_eq!(out.len(), 8);
    for (i, r) in out.iter().enumerate() {
        if i == 3 {
            assert!(r.is_err(), "panicking job must surface as Err");
        } else {
            assert_eq!(*r.as_ref().unwrap(), i * 7, "sibling job {i} lost");
        }
    }
}

#[test]
fn failing_scenario_is_captured_not_fatal() {
    let mut cfg = SweepConfig::smoke();
    cfg.algos = vec![SweepAlgo::KMeans, SweepAlgo::Dbscan];
    cfg.techs = vec!["academic-22nm".into()];
    cfg.rail_modes = vec![RailMode::Runtime];
    cfg.policies = vec![RecoveryPolicy::None];
    // k far beyond the MAC count: the kmeans scenario must fail with a
    // structured record while the dbscan scenario completes.
    cfg.k = 100_000;
    let rep = run_sweep(&cfg).unwrap();
    assert_eq!(rep.scenarios.len(), 2);
    assert_eq!(rep.failed_count, 1);
    assert_eq!(rep.ok_count, 1);
    let failed = rep.scenarios.iter().find(|r| r.outcome.is_err()).unwrap();
    assert_eq!(failed.scenario.algo, SweepAlgo::KMeans);
    assert!(
        failed.outcome.as_ref().err().unwrap().contains("exceeds"),
        "error message lost: {:?}",
        failed.outcome
    );
    // The winner table still forms from the surviving scenario, and the
    // JSON renders the failure as a structured record.
    assert_eq!(rep.winners.len(), 1);
    assert_eq!(rep.winners[0].best_power_algo, "dbscan");
    let json = bench_sweep_json(&rep);
    assert!(json.contains("\"status\": \"failed\""));
    assert!(json.contains("\"status\": \"ok\""));
}

#[test]
fn rail_mode_axis_compares_static_vs_runtime() {
    let mut cfg = SweepConfig::smoke();
    cfg.algos = vec![SweepAlgo::EqualQuantile];
    cfg.techs = vec!["academic-22nm".into()];
    cfg.policies = vec![RecoveryPolicy::None];
    let rep = run_sweep(&cfg).unwrap(); // 1 algo x 1 tech x both rail modes
    assert_eq!(rep.failed_count, 0);
    assert_eq!(rep.scenarios.len(), 2);
    let get = |m: RailMode| {
        rep.scenarios
            .iter()
            .find(|r| r.scenario.rail_mode == m)
            .unwrap()
            .outcome
            .as_ref()
            .unwrap()
    };
    let st = get(RailMode::Static);
    let rt = get(RailMode::Runtime);
    // Runtime rails respect every partition's frontier; blind static
    // stepping over the VTR critical region dips below it — the gap the
    // paper's runtime scheme exists to close.
    for (&v, &f) in rt.rails.iter().zip(&rt.frontiers) {
        assert!(v >= f - 1e-9, "runtime rail {v} below frontier {f}");
    }
    assert!(
        st.rails.iter().zip(&st.frontiers).any(|(v, f)| v < f),
        "static-only rails never dip below a frontier — the runtime \
         stage would have nothing to fix: {:?} vs {:?}",
        st.rails,
        st.frontiers
    );
    // Both comparison groups form their own winner rows.
    assert!(rep.winners.iter().any(|w| w.rail_mode == "static"));
    assert!(rep.winners.iter().any(|w| w.rail_mode == "runtime"));
}

#[test]
fn recovery_policy_axis_descends_below_the_frontier_on_45nm() {
    // academic-45nm: one guard-band step is provably non-silent inside
    // the Razor shadow window, so the TE-Drop arm's rail+policy
    // co-optimization must land strictly below the None arm's rails.
    let mut cfg = SweepConfig::smoke();
    cfg.algos = vec![SweepAlgo::EqualQuantile];
    cfg.techs = vec!["academic-45nm".into()];
    cfg.rail_modes = vec![RailMode::Runtime];
    cfg.policies = vec![RecoveryPolicy::None, RecoveryPolicy::TeDrop];
    let rep = run_sweep(&cfg).unwrap();
    assert_eq!(rep.failed_count, 0, "both policy arms must complete");
    assert_eq!(rep.scenarios.len(), 2);
    let get = |p: RecoveryPolicy| {
        rep.scenarios
            .iter()
            .find(|r| r.scenario.policy == p)
            .unwrap()
            .outcome
            .as_ref()
            .unwrap()
    };
    let none = get(RecoveryPolicy::None);
    let drop = get(RecoveryPolicy::TeDrop);
    let sum = |rails: &[f64]| rails.iter().sum::<f64>();
    assert!(
        sum(&drop.rails) < sum(&none.rails) - 1e-9,
        "TE-Drop rails {:?} must sit below the None rails {:?}",
        drop.rails,
        none.rails
    );
    assert!(
        drop.power_mw < none.power_mw,
        "the voltage headroom must buy power: {} vs {} mW",
        drop.power_mw,
        none.power_mw
    );
    assert!(drop.accuracy_loss.is_finite() && drop.accuracy_loss >= 0.0);
    assert_eq!(drop.replay_overhead, 0.0, "TE-Drop never replays");
    // Each policy forms its own winner row — the energy-vs-accuracy
    // frontier the report renders.
    assert!(rep.winners.iter().any(|w| w.policy == "none"));
    assert!(rep.winners.iter().any(|w| w.policy == "te-drop"));
}

#[test]
fn memory_rail_axis_prices_the_split_arm_strictly_cheaper() {
    // S24: the same scenario measured under both memory-rail arms. The
    // logic-side measurement is shared (the substrate cache is not
    // keyed on the memory arm), so the arms differ only in the BRAM
    // terms — and the split arm, parked at the guard knee, must win on
    // combined power at identical joint accuracy loss.
    let mut cfg = SweepConfig::smoke();
    cfg.algos = vec![SweepAlgo::EqualQuantile];
    cfg.techs = vec!["academic-22nm".into()];
    cfg.rail_modes = vec![RailMode::Runtime];
    cfg.policies = vec![RecoveryPolicy::None];
    cfg.memory_rails = MemoryRailMode::all();
    let rep = run_sweep(&cfg).unwrap();
    assert_eq!(rep.failed_count, 0, "both memory arms must complete");
    assert_eq!(rep.scenarios.len(), 2);
    let get = |m: MemoryRailMode| {
        rep.scenarios
            .iter()
            .find(|r| r.scenario.memory_rail == m)
            .unwrap()
            .outcome
            .as_ref()
            .unwrap()
    };
    let nom = get(MemoryRailMode::Nominal);
    let split = get(MemoryRailMode::Split);
    // Identical logic-side measurement, different memory pricing.
    assert_eq!(nom.power_mw, split.power_mw);
    assert_eq!(nom.accuracy_loss, split.accuracy_loss);
    assert!(split.memory_rail_v < nom.memory_rail_v);
    assert!(
        split.memory_mw < nom.memory_mw,
        "knee-parked buffers must draw less: {} vs {} mW",
        split.memory_mw,
        nom.memory_mw
    );
    assert!(split.total_power_mw < nom.total_power_mw);
    // At the knee the fault model is exactly inert, so the joint loss
    // matches the nominal arm's bit for bit.
    assert_eq!(split.total_loss, nom.total_loss);
    // Each memory arm forms its own winner row carrying the combined
    // (logic + memory) ranking.
    for arm in ["nominal", "split"] {
        let w = rep.winners.iter().find(|w| w.memory_rail == arm).unwrap();
        assert_eq!(w.best_total_algo, "equal-quantile");
        assert!(w.best_total_mw >= w.best_power_mw);
        assert!(w.best_total_loss.is_finite());
    }
}

#[test]
fn every_algorithm_calibrates_rails_at_or_above_its_frontier() {
    let mut cfg = SweepConfig::smoke();
    cfg.algos = SweepAlgo::all();
    cfg.techs = vec!["academic-22nm".into()];
    cfg.sizes = vec![16];
    cfg.shifts = vec![0.45];
    cfg.rail_modes = vec![RailMode::Runtime];
    // Policy None: a recovering policy deliberately descends below the
    // frontier (see the recovery-axis test), which this invariant pins
    // down for the policy-free path.
    cfg.policies = vec![RecoveryPolicy::None];
    let rep = run_sweep(&cfg).unwrap();
    assert_eq!(rep.failed_count, 0, "all five algorithms must complete");
    for r in &rep.scenarios {
        let res = r.outcome.as_ref().unwrap();
        let name = r.scenario.algo.name();
        assert!(res.k >= 1, "{name}: no partitions");
        assert_eq!(res.rails.len(), res.frontiers.len(), "{name}");
        for (i, (&v, &f)) in res.rails.iter().zip(&res.frontiers).enumerate() {
            assert!(
                v >= f - 1e-9,
                "{name} partition {i}: rail {v:.4} V below frontier {f:.4} V"
            );
        }
        assert!(
            res.power_mw < res.baseline_mw,
            "{name}: calibrated power must beat the unscaled baseline"
        );
        // The clustering -> partition path produced a total labelling:
        // rails exist for exactly k partitions.
        assert_eq!(res.rails.len(), res.k, "{name}");
    }
}
